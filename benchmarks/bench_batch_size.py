"""Fig. 5: sensitivity to output nodes per batch (node-wise IBMB).
The paper finds the impact minor — especially above ~1000 outputs."""
from __future__ import annotations

from typing import List

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.graph.datasets import get_dataset


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    va = ibmb_pipeline(ds, "node").preprocess("val", for_inference=True)
    rows: List[Row] = []
    for cap in (64, 128, 256, 512):
        pipe = ibmb_pipeline(ds, "node", max_outputs_per_batch=cap)
        tr = pipe.preprocess("train")
        res, _ = train_with(ds, tr, va)
        rows.append((f"batch_size/outputs_{cap}", res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc, num_batches=len(tr))))
    return rows
