"""Fig. 4: convergence vs label rate. IBMB scales with the number of output
nodes; global methods (Cluster-GCN) scale with graph size — the gap must grow
as the training set shrinks."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.graph.datasets import get_dataset, GraphDataset
from repro.graph.sampling import make_batcher


def _subsample(ds: GraphDataset, frac: float, seed: int = 0) -> GraphDataset:
    rng = np.random.default_rng(seed)
    tr = ds.splits["train"]
    keep = np.sort(rng.choice(tr, size=max(32, int(len(tr) * frac)),
                              replace=False))
    return GraphDataset(ds.name + f"-lr{frac}", ds.graph, ds.norm_graph,
                        ds.features, ds.labels,
                        {**ds.splits, "train": keep})


def run() -> List[Row]:
    base = get_dataset(DS_MAIN)
    rows: List[Row] = []
    for frac in (1.0, 0.3, 0.1):
        ds = _subsample(base, frac)
        va = ibmb_pipeline(ds, "node").preprocess("val", for_inference=True)

        t0 = time.time()
        pipe = ibmb_pipeline(ds, "node")
        tr = pipe.preprocess("train")
        prep_ibmb = time.time() - t0
        res_i, _ = train_with(ds, tr, va)

        t0 = time.time()
        bt = make_batcher("cluster_gcn", ds, num_batches=8)
        prep_c = time.time() - t0
        res_c, _ = train_with(ds, bt.epoch_batches(0), va)

        rows.append((f"label_rate/ibmb_node@{frac}",
                     res_i.time_per_epoch * 1e6,
                     fmt(val_acc=res_i.best_val_acc, preprocess_s=prep_ibmb,
                         train_nodes=len(ds.splits['train']))))
        rows.append((f"label_rate/cluster_gcn@{frac}",
                     res_c.time_per_epoch * 1e6,
                     fmt(val_acc=res_c.best_val_acc, preprocess_s=prep_c,
                         train_nodes=len(ds.splits['train']))))
    return rows
