"""Dynamic-graph plan refresh (DESIGN.md §10): refresh-vs-rebuild wall time
and stale-vs-refreshed-vs-rebuilt accuracy, on a delta touching ≤10% of the
split's output nodes.

The claim being measured: ``IBMBPipeline.refresh(plan, delta)`` — the
incremental delta-PPR path that re-pushes only dirty roots and rebuilds only
dirty batches — beats applying the delta and re-running ``pipeline.plan()``
from scratch, while producing a plan whose accuracy equals the rebuilt one
(tools/check_bench_json.py --mode update asserts both). ``benchmarks/run.py``
writes the records to ``BENCH_update.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.core import GraphDelta, IBMBPipeline, IBMBConfig
from repro.graph.datasets import get_dataset
from repro.serve import GNNInferenceEngine

JSON_RECORDS: List[dict] = []

FEAT_FRAC = 0.05        # outputs getting feature updates
EDGE_EDITS = 2          # undirected inserts AND deletes (structural delta)

# Inference-serving plans never consume the TSP anneal (GNNTrainer.fit
# derives its own per-epoch orders; evaluate/engine ignore the schedule),
# so the refresh-vs-rebuild A/B runs with schedule="none" — otherwise both
# sides are dominated by re-annealing a schedule nobody reads.
PIPE_KW = dict(schedule="none")


def _record(name: str, us: float, **derived) -> Row:
    JSON_RECORDS.append({"op": name, "us_per_call": float(us), **derived})
    return (name, us, fmt(**derived))


def _payload_delta(ds, rng) -> GraphDelta:
    """Feature noise + a label flip on FEAT_FRAC of the test outputs — the
    steady-state dynamic case (drifting node payloads, fixed topology)."""
    test = ds.splits["test"]
    n_feat = max(1, int(FEAT_FRAC * len(test)))
    feat_nodes = np.sort(rng.choice(test, size=n_feat, replace=False))
    feat_values = ds.features[feat_nodes] \
        + rng.normal(0, 2.0, (n_feat, ds.feat_dim)).astype(np.float32)
    return GraphDelta(
        feat_nodes=feat_nodes, feat_values=feat_values,
        label_nodes=feat_nodes[:1],
        label_values=np.array(
            [(int(ds.labels[feat_nodes[0]]) + 1) % ds.num_classes]))


def _structural_delta(ds, rng) -> GraphDelta:
    """The payload delta plus EDGE_EDITS edge inserts/deletes anchored at
    test outputs — still ≤10% of output nodes touched directly, but the
    influence scores (and hence the partition) must be re-derived."""
    base = _payload_delta(ds, rng)
    deletes, inserts = [], []
    anchors = rng.choice(ds.splits["test"], size=EDGE_EDITS, replace=False)
    for a in anchors:
        nb = ds.graph.neighbors(int(a))
        if len(nb):
            deletes.append([int(a), int(nb[0])])
        while True:
            b = int(rng.integers(0, ds.num_nodes))
            if b != int(a) and not np.isin(b, nb):
                inserts.append([int(a), b])
                break
    return dataclasses.replace(
        base, edge_inserts=np.array(inserts, np.int64),
        edge_deletes=np.array(deletes, np.int64))


def _refresh_vs_rebuild(name, ds, delta, backend, trainer=None, params=None,
                        **pipe_kw) -> Row:
    pipe_kw = dict(PIPE_KW, **pipe_kw)
    pipe = ibmb_pipeline(ds, "node", backend=backend, **pipe_kw)
    stale_plan = pipe.plan("test", for_inference=True)

    t0 = time.perf_counter()
    refreshed, audit = pipe.refresh(stale_plan, delta)
    refresh_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    ds_new = delta.apply(ds)
    rebuilt = ibmb_pipeline(ds_new, "node", backend=backend,
                            **pipe_kw).plan("test", for_inference=True)
    rebuild_us = (time.perf_counter() - t0) * 1e6
    assert rebuilt.fingerprint == refreshed.fingerprint

    test = ds.splits["test"]
    touched = delta.feat_nodes if delta.feat_nodes is not None \
        else np.zeros(0, np.int64)
    frac = (len(touched) +
            len(np.intersect1d(delta.touched_nodes(), test))) / len(test)
    derived = dict(
        rebuild_us=rebuild_us, speedup=rebuild_us / max(refresh_us, 1e-9),
        rebuilt=len(audit.rebuilt), patched=len(audit.patched),
        untouched=len(audit.untouched), dirty_roots=audit.dirty_roots,
        frac_outputs_touched=float(frac), num_batches=len(refreshed))
    if trainer is not None:
        # stale = keep serving the pre-delta plan; the refreshed plan must
        # recover exactly the rebuilt plan's accuracy on the new graph
        labels_new = ds_new.labels
        for key, plan in (("stale_acc", stale_plan),
                          ("refreshed_acc", refreshed),
                          ("rebuilt_acc", rebuilt)):
            eng = GNNInferenceEngine(plan, trainer.cfg, params,
                                     backend=backend,
                                     cache_batches=len(plan))
            ids = np.asarray(plan.routing.node_ids)
            pred = eng.query(ids).argmax(-1)
            derived[key] = float((pred == labels_new[ids]).mean())
    return _record(f"update/{name}", refresh_us, **derived)


def run() -> List[Row]:
    JSON_RECORDS.clear()
    ds = get_dataset(DS_MAIN)

    # one trained model serves every accuracy row (the paper's amortization:
    # preprocessing AND weights are reused across graph versions)
    pipe = ibmb_pipeline(ds, "node")
    res, trainer = train_with(ds, pipe.plan("train"),
                              pipe.plan("val", for_inference=True))

    # smaller batches than the training defaults so the delta has locality
    # to exploit (a plan of 3 giant batches is all-dirty by construction)
    kw = dict(max_outputs_per_batch=64)
    payload = _payload_delta(ds, np.random.default_rng(0))
    structural = _structural_delta(ds, np.random.default_rng(1))
    rows = [
        # the steady-state dynamic case: payload drift, topology fixed —
        # refresh patches in place and must beat rebuild by a wide margin
        _refresh_vs_rebuild("refresh_node_payload", ds, payload, "segment",
                            trainer=trainer, params=res.params, **kw),
        _refresh_vs_rebuild("refresh_node_bcsr_payload", ds, payload, "bcsr",
                            trainer=trainer, params=res.params, **kw),
        # the boundary case: edge edits perturb the influence pairs, the
        # greedy partition cascades, and refresh legitimately degrades to
        # ~rebuild cost (only the incremental PPR push is saved). Reported
        # so the trajectory shows WHERE the minimal-dirty-set win ends;
        # check_bench_json asserts speedup only where untouched > 0.
        _refresh_vs_rebuild("refresh_node_structural", ds, structural,
                            "segment", trainer=trainer, params=res.params,
                            **kw),
    ]
    return rows
