"""Fig. 6: output-node partitioning ablation — node-wise vs batch-wise vs
FIXED RANDOM batches. Random must converge slower / plateau lower."""
from __future__ import annotations

from typing import List

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, time_to_acc, train_with
from repro.graph.datasets import get_dataset


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    va = ibmb_pipeline(ds, "node").preprocess("val", for_inference=True)
    rows: List[Row] = []
    for variant, kw in (("node", {}), ("batch", {"num_batches": 8}),
                        ("random", {})):
        pipe = ibmb_pipeline(ds, variant, **kw)
        tr = pipe.preprocess("train")
        res, _ = train_with(ds, tr, va)
        t_target = time_to_acc(res.history, 0.75)
        rows.append((f"ablation/partition_{variant}",
                     res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc,
                         time_to_075_s=(t_target if t_target is not None
                                        else float("nan")))))
    return rows
