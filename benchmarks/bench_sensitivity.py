"""Table 5: auxiliary-node selection sensitivity — PPR teleport α sweep and
the heat-kernel alternative (batch-wise IBMB). The paper: 'IBMB is very
robust to this choice'."""
from __future__ import annotations

from typing import List

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline, train_with
from repro.graph.datasets import get_dataset


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    va = ibmb_pipeline(ds, "node").preprocess("val", for_inference=True)
    rows: List[Row] = []
    for alpha in (0.05, 0.15, 0.25, 0.35):
        pipe = ibmb_pipeline(ds, "batch", num_batches=8, alpha=alpha)
        res, _ = train_with(ds, pipe.preprocess("train"), va)
        rows.append((f"sensitivity/ppr_a{alpha}", res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc)))
    for t in (1.0, 3.0, 5.0):
        pipe = ibmb_pipeline(ds, "batch", num_batches=8, diffusion="heat",
                             heat_t=t)
        res, _ = train_with(ds, pipe.preprocess("train"), va)
        rows.append((f"sensitivity/heat_t{t}", res.time_per_epoch * 1e6,
                     fmt(val_acc=res.best_val_acc)))
    return rows
