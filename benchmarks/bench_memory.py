"""Table 6: main-memory usage of the batch cache per method. IBMB can use
MORE memory (overlapping batches) or LESS (ignores irrelevant graph parts)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import DS_MAIN, Row, fmt, ibmb_pipeline
from repro.core.batches import BatchCache
from repro.graph.datasets import get_dataset
from repro.graph.sampling import make_batcher


def run() -> List[Row]:
    ds = get_dataset(DS_MAIN)
    rows: List[Row] = []

    def add(name, batches, prep_s):
        cache = BatchCache(batches)
        nodes = sum(b.num_real_nodes for b in batches)
        rows.append((f"memory/{name}", prep_s * 1e6,
                     fmt(cache_mb=cache.nbytes() / 1e6,
                         total_real_nodes=nodes,
                         num_batches=len(batches))))

    t0 = time.time()
    add("ibmb_node", ibmb_pipeline(ds, "node").preprocess("train"),
        time.time() - t0)
    t0 = time.time()
    add("ibmb_batch",
        ibmb_pipeline(ds, "batch", num_batches=8).preprocess("train"),
        time.time() - t0)
    for name, kw in [("cluster_gcn", {"num_batches": 8}),
                     ("neighbor_sampling", {"num_batches": 8}),
                     ("graphsaint_rw", {"num_steps": 8, "batch_roots": 400}),
                     ("shadow_ppr", {"outputs_per_batch": 256})]:
        t0 = time.time()
        bt = make_batcher(name, ds, **kw)
        add(name, bt.epoch_batches(0), time.time() - t0)
    return rows
